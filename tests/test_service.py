"""The asynchronous request-plan sampling service: concurrency & determinism
(bit-identical results under interleaving / sharing / coalescing / window
depth), SamplingSpec + config validation, service-level stats aggregation."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # minimal envs: deterministic fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.api import (
    GLISPConfig,
    GLISPSystem,
    SampleRequest,
    SamplingSpec,
)
from repro.core.sampling import ServerStats


@pytest.fixture(scope="module")
def svc_graph():
    from repro.graph import power_law_graph

    g = power_law_graph(1200, avg_degree=8, seed=11, feat_dim=16, num_classes=4)
    g.labels = g.vertex_types.astype(np.int32)
    return g


def _build(g, **overrides):
    base = dict(num_parts=4, fanouts=(8, 4), batch_size=128)
    base.update(overrides)
    return GLISPSystem.build(g, GLISPConfig(**base))


def _assert_same_subgraph(a, b):
    np.testing.assert_array_equal(a.seeds, b.seeds)
    assert len(a.hops) == len(b.hops)
    for ha, hb in zip(a.hops, b.hops):
        np.testing.assert_array_equal(ha.src, hb.src)
        np.testing.assert_array_equal(ha.dst, hb.dst)
        if ha.eid is not None or hb.eid is not None:
            np.testing.assert_array_equal(ha.eid, hb.eid)


EC = dict(partitioner="ldg", sampler="edge_cut", num_parts=3)


# ---------------------------------------------------------------------------
# SamplingSpec + GLISPConfig validation
# ---------------------------------------------------------------------------


def test_sampling_spec_validation():
    SamplingSpec(fanouts=(5, 3)).validate()
    with pytest.raises(ValueError, match="fanouts"):
        SamplingSpec(fanouts=()).validate()
    with pytest.raises(ValueError, match="fanouts"):
        SamplingSpec(fanouts=(5, 0)).validate()
    with pytest.raises(ValueError, match="direction"):
        SamplingSpec(direction="sideways").validate()
    with pytest.raises(ValueError, match="replace"):
        SamplingSpec(weighted=True, replace=True).validate()


def test_config_positivity_checks():
    for field in (
        "chunk_rows",
        "infer_batch_size",
        "vertex_quantum",
        "edge_quantum",
        "batch_size",
        "inflight",
    ):
        with pytest.raises(ValueError, match=field):
            GLISPConfig(**{field: 0}).validate()
    with pytest.raises(ValueError, match="max_server_batch"):
        GLISPConfig(max_server_batch=-1).validate()
    # spec fields are validated through the config too
    with pytest.raises(ValueError, match="replace"):
        GLISPConfig(weighted=True, sample_replace=True).validate()
    GLISPConfig(coalesce=False, max_server_batch=64, inflight=4).validate()


def test_config_spec_roundtrip():
    cfg = GLISPConfig(fanouts=(15, 10), weighted=True, direction="in")
    spec = cfg.sampling_spec()
    assert spec == SamplingSpec(fanouts=(15, 10), weighted=True, direction="in")
    assert cfg.sampling_spec(fanouts=[3], weighted=False).fanouts == (3,)


# ---------------------------------------------------------------------------
# ticket lifecycle
# ---------------------------------------------------------------------------


def test_ticket_lifecycle_and_request_object(svc_graph):
    system = _build(svc_graph)
    spec = SamplingSpec(fanouts=(6, 3))
    req = SampleRequest(
        seeds=np.arange(40), spec=spec, key=(1, 2)
    )
    ticket = system.service.submit(req)
    assert not ticket.done()
    assert system.service.inflight() == 1
    sub = ticket.result()
    assert ticket.done()
    assert system.service.inflight() == 0
    assert len(sub.hops) == 2
    # a second result() call returns the same finished object, no re-run
    assert ticket.result() is sub
    with pytest.raises(ValueError, match="SamplingSpec"):
        system.service.submit(np.arange(5))


def test_ticket_cancel(svc_graph):
    system = _build(svc_graph)
    spec = SamplingSpec(fanouts=(6, 3))
    keep = system.submit(np.arange(40), spec, key=(1,))
    drop = system.submit(np.arange(40, 80), spec, key=(2,))
    drop.cancel()
    assert system.service.inflight() == 1
    sub = keep.result()  # cancelled request consumes no further rounds
    assert len(sub.hops) == 2
    with pytest.raises(RuntimeError, match="cancelled"):
        drop.result()
    # a kept request is bit-identical to a run that never saw the cancelled
    # sibling (per-request RNG keys make cancellation invisible)
    want = _build(svc_graph).submit(np.arange(40), spec, key=(1,)).result()
    _assert_same_subgraph(sub, want)


def test_spec_and_overrides_conflict(svc_graph):
    system = _build(svc_graph)
    spec = SamplingSpec(fanouts=(4,))
    with pytest.raises(ValueError, match="not both"):
        system.sample(np.arange(10), fanouts=[5], spec=spec)
    with pytest.raises(ValueError, match="not both"):
        system.submit(np.arange(10), spec, weighted=True)
    with pytest.raises(ValueError, match="not both"):
        system.loader(np.arange(10), fanouts=(5,), spec=spec)


def test_submit_key_normalization(svc_graph):
    system = _build(svc_graph)
    spec = SamplingSpec(fanouts=(4,))
    a = system.submit(np.arange(30), spec, key=7).result()
    b = _build(svc_graph).submit(np.arange(30), spec, key=(7,)).result()
    _assert_same_subgraph(a, b)  # int keys normalize to 1-tuples
    with pytest.raises(TypeError, match="key"):
        system.submit(np.arange(5), spec, key="nope")


# ---------------------------------------------------------------------------
# concurrency: in-flight requests are bit-identical to serial submission
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("overrides", [{}, EC])
def test_concurrent_inflight_matches_serial(svc_graph, overrides):
    spec = SamplingSpec(fanouts=(8, 4))
    seedsets = [np.arange(100), np.arange(50, 150), np.arange(120, 220)]
    keys = [(11,), (12,), (13,)]

    serial = _build(svc_graph, **overrides)
    want = [
        serial.submit(s, spec, key=k).result() for s, k in zip(seedsets, keys)
    ]

    conc = _build(svc_graph, **overrides)
    tickets = [conc.submit(s, spec, key=k) for s, k in zip(seedsets, keys)]
    assert conc.service.inflight() == 3  # >= 2 concurrent in-flight requests
    got = [t.result() for t in reversed(tickets)][::-1]
    for a, b in zip(got, want):
        _assert_same_subgraph(a, b)
    # overlapping the requests lowers modeled parallel latency, never raises
    assert conc.service.parallel_work <= serial.service.parallel_work + 1e-9


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), resolve_first=st.integers(0, 2))
def test_property_interleaving_invariance(seed, resolve_first):
    """Any submission/resolution interleaving of 3 requests is bit-identical
    to serial one-at-a-time submission with the same keys."""
    from repro.graph import power_law_graph

    g = power_law_graph(500, avg_degree=6, seed=3, feat_dim=4, num_classes=2)
    rng = np.random.default_rng(seed)
    spec = SamplingSpec(fanouts=(5, 3), weighted=bool(seed % 2))
    seedsets = [
        np.sort(rng.choice(g.num_vertices, 60, replace=False)) for _ in range(3)
    ]
    keys = [(seed, i) for i in range(3)]

    serial = _build(g, num_parts=3)
    want = [
        serial.submit(s, spec, key=k).result() for s, k in zip(seedsets, keys)
    ]

    conc = _build(g, num_parts=3)
    tickets = [conc.submit(s, spec, key=k) for s, k in zip(seedsets, keys)]
    order = [resolve_first] + [i for i in range(3) if i != resolve_first]
    got = [None] * 3
    for i in order:
        got[i] = tickets[i].result()
    for a, b in zip(got, want):
        _assert_same_subgraph(a, b)


# ---------------------------------------------------------------------------
# coalescing: dispatch accounting only — results bit-equivalent
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("overrides", [{}, EC])
@pytest.mark.parametrize("weighted", [False, True])
def test_coalesced_vs_uncoalesced_equivalence(svc_graph, overrides, weighted):
    spec = SamplingSpec(fanouts=(8, 4), weighted=weighted)
    seedsets = [np.arange(100), np.arange(50, 150)]  # shared frontier seeds
    keys = [(5,), (6,)]

    def run(coalesce):
        system = _build(svc_graph, coalesce=coalesce, **overrides)
        tickets = [
            system.submit(s, spec, key=k) for s, k in zip(seedsets, keys)
        ]
        return [t.result() for t in tickets], system.service.stats()

    got_on, stats_on = run(True)
    got_off, stats_off = run(False)
    for a, b in zip(got_on, got_off):
        _assert_same_subgraph(a, b)
    # duplicated frontier seeds across the in-flight requests are charged
    # once when coalescing; payload counters are identical either way
    assert stats_on.seeds < stats_off.seeds
    assert stats_on.edges_returned == stats_off.edges_returned
    assert stats_on.bytes_out == stats_off.bytes_out


def test_max_server_batch_split(svc_graph):
    """Splitting bounds per-dispatch size; results stay deterministic and
    respect fanouts, and full fanout stays lossless."""
    spec = SamplingSpec(fanouts=(8, 4))
    a = _build(svc_graph, max_server_batch=16).submit(
        np.arange(120), spec, key=(3,)
    ).result()
    b = _build(svc_graph, max_server_batch=16).submit(
        np.arange(120), spec, key=(3,)
    ).result()
    _assert_same_subgraph(a, b)
    for f, hop in zip((8, 4), a.hops):
        if hop.src.shape[0]:
            assert np.unique(hop.src, return_counts=True)[1].max() <= f
    # chunked dispatch raises the per-server request count
    sys_split = _build(svc_graph, max_server_batch=16, coalesce=False)
    sys_whole = _build(svc_graph, coalesce=False)
    sys_split.sample(np.arange(200), fanouts=[6])
    sys_whole.sample(np.arange(200), fanouts=[6])
    assert sys_split.service.stats().requests > sys_whole.service.stats().requests
    # lossless at full fanout even with chunking
    sub = _build(svc_graph, max_server_batch=8).sample(
        np.arange(20), fanouts=[10**9]
    )
    hop = sub.hops[0]
    for v in range(20):
        got = sorted(hop.dst[hop.src == v].tolist())
        assert got == sorted(svc_graph.neighbors(v, "out").tolist())


# ---------------------------------------------------------------------------
# loaders: window depth / prefetch depth / sharing never change the stream
# ---------------------------------------------------------------------------


def _collect(pipeline, epochs=1):
    return [(s, b) for s, b in pipeline.batches(epochs)]


def _assert_same_stream(a, b):
    assert len(a) == len(b) > 0
    for (s1, x1), (s2, x2) in zip(a, b):
        np.testing.assert_array_equal(s1, s2)
        np.testing.assert_array_equal(x1.feats, x2.feats)
        np.testing.assert_array_equal(x1.labels, x2.labels)
        for k in range(len(x1.layer_dst)):
            np.testing.assert_array_equal(x1.layer_dst[k], x2.layer_dst[k])
            np.testing.assert_array_equal(x1.layer_src[k], x2.layer_src[k])


def test_loader_invariant_to_inflight_depth(svc_graph):
    ids = np.arange(800)
    runs = [
        _collect(
            _build(svc_graph).loader(
                ids, num_layers=2, prefetch=0, seed=5, inflight=w
            )
        )
        for w in (1, 2, 5)
    ]
    _assert_same_stream(runs[0], runs[1])
    _assert_same_stream(runs[0], runs[2])


def test_loader_invariant_to_prefetch_with_window(svc_graph):
    ids = np.arange(800)
    serial = _collect(
        _build(svc_graph).loader(ids, num_layers=2, prefetch=0, seed=5, inflight=3)
    )
    prefetched = _collect(
        _build(svc_graph).loader(ids, num_layers=2, prefetch=3, seed=5, inflight=3)
    )
    _assert_same_stream(serial, prefetched)


@pytest.mark.parametrize("overrides", [{}, EC])
def test_shared_service_loaders_match_private(svc_graph, overrides):
    """Two loaders sharing ONE SamplingService produce streams bit-identical
    to the same loaders on private services, even with their requests
    interleaved in flight (per-request RNG keys carry the whole contract)."""
    ids_a, ids_b = np.arange(400), np.arange(400, 800)
    shared = _build(svc_graph, **overrides)
    la = shared.loader(ids_a, num_layers=2, prefetch=0, seed=3, inflight=2)
    lb = shared.loader(ids_b, num_layers=2, prefetch=0, seed=3, inflight=2)
    ita, itb = la.batches(1), lb.batches(1)
    out_a, out_b = [], []
    while True:  # interleave consumption so both loaders' requests coexist
        nxt_a, nxt_b = next(ita, None), next(itb, None)
        if nxt_a is None and nxt_b is None:
            break
        if nxt_a is not None:
            out_a.append(nxt_a)
        if nxt_b is not None:
            out_b.append(nxt_b)
    priv_a = _collect(
        _build(svc_graph, **overrides).loader(
            ids_a, num_layers=2, prefetch=0, seed=3, inflight=2
        )
    )
    priv_b = _collect(
        _build(svc_graph, **overrides).loader(
            ids_b, num_layers=2, prefetch=0, seed=3, inflight=2
        )
    )
    _assert_same_stream(out_a, priv_a)
    _assert_same_stream(out_b, priv_b)


# ---------------------------------------------------------------------------
# replace policy
# ---------------------------------------------------------------------------


def test_replace_sampling(svc_graph):
    system = _build(svc_graph)
    sub = system.sample(np.arange(200), fanouts=[12], replace=True)
    hop = sub.hops[0]
    _, counts = np.unique(hop.src, return_counts=True)
    assert counts.max() <= 12
    # every sampled edge is real
    np.testing.assert_array_equal(svc_graph.src[hop.eid], hop.src)
    np.testing.assert_array_equal(svc_graph.dst[hop.eid], hop.dst)
    # with replacement a low-degree seed's draws must repeat eventually
    assert len(set(zip(hop.src.tolist(), hop.dst.tolist()))) < hop.src.shape[0]


# ---------------------------------------------------------------------------
# stats: service-level aggregation + raw client reset discipline
# ---------------------------------------------------------------------------


def test_service_stats_merge(svc_graph):
    system = _build(svc_graph)
    system.sample(np.arange(100))
    merged = system.service.stats()
    assert isinstance(merged, ServerStats)
    per = [s.stats for s in system.service.servers]
    assert merged.requests == sum(p.requests for p in per) > 0
    assert merged.seeds == sum(p.seeds for p in per)
    assert merged.work_units == pytest.approx(sum(p.work_units for p in per))
    assert merged.edges_returned == sum(p.edges_returned for p in per)
    system.reset_stats()
    z = system.service.stats()
    assert z.requests == z.seeds == z.edges_returned == 0
    assert system.service.parallel_work == 0.0


def test_raw_client_reset_clears_work(svc_graph):
    from repro.core.partition import adadne
    from repro.core.sampling import (
        GatherApplyClient,
        SamplingServer,
        VertexRouter,
    )
    from repro.graph import build_partitions

    ep = adadne(svc_graph, 3, seed=1)
    parts = build_partitions(svc_graph, ep, 3)
    client = GatherApplyClient(
        [SamplingServer(p, seed=0) for p in parts],
        VertexRouter(svc_graph, ep, 3),
        seed=0,
    )
    client.sample_khop(np.arange(100), [6, 3])
    assert client.parallel_work > 0 and client.total_work > 0
    client.reset_stats()  # clears counters AND the work accumulators
    assert client.parallel_work == 0.0
    assert client.total_work == 0.0
    assert client.server_workloads().sum() == 0


# ---------------------------------------------------------------------------
# training through the windowed service path
# ---------------------------------------------------------------------------


def test_trainer_inflight_matches_blocking(svc_graph):
    from repro.models.gnn import GNNModel
    from repro.train.optim import AdamWConfig

    g = svc_graph
    model = GNNModel("sage", 16, hidden=16, num_layers=2, num_classes=4)
    losses = []
    for w in (1, 3):
        tr = _build(g).trainer(
            model,
            np.arange(600),
            opt=AdamWConfig(lr=3e-3),
            prefetch=0,
            inflight=w,
        )
        log = tr.train(epochs=1, log_every=1)
        losses.append(log.losses)
    np.testing.assert_allclose(losses[0], losses[1])
