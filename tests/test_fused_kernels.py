"""Fused GNN kernels vs their jnp oracles + the deterministic autotuner.

Property sweeps cover ragged edge counts (padding tails of every length,
including all-padding and zero-edge inputs), both dtypes the engine
dispatches (f32/bf16), and empty segments; the autotuner tests pin the
determinism contract: same inputs -> same config, memory hit on the second
call, artifact hit after a simulated process restart.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # minimal envs: deterministic fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.kernels import autotune as at
from repro.kernels.fused_gnn import (
    gat_softmax_aggregate_pallas,
    gather_spmm_pallas,
    gather_spmm_ragged_pallas,
    segment_max_pallas,
    segment_spmm_ragged_pallas,
)
from repro.kernels.ref import (
    gat_softmax_aggregate_ref,
    gather_spmm_ref,
    segment_max_ref,
    segment_spmm_ref,
)

_TOL = {jnp.float32: 1e-5, jnp.bfloat16: 2e-2}


def _close(got, want, dtype=jnp.float32):
    tol = _TOL[dtype]
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol * 10,
    )


def _edges(m, n, valid, seed, d=None, dtype=jnp.float32):
    """idx/seg with a padding tail (-1) after ``valid`` real edges."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n, max(m, 1)).astype(np.int32)[:m]
    seg = np.sort(rng.integers(0, n, max(m, 1))).astype(np.int32)[:m]
    idx[valid:] = -1
    seg[valid:] = -1
    out = [jnp.asarray(idx), jnp.asarray(seg)]
    if d is not None:
        feats = jnp.asarray(rng.standard_normal((n, d)), dtype=dtype)
        msg = jnp.asarray(rng.standard_normal((m, d)), dtype=dtype)
        logits = jnp.asarray(rng.standard_normal(m), dtype=dtype)
        out += [feats, msg, logits]
    return out


# ---------------------------------------------------------------------------
# property sweeps: ragged edge counts, random segment maps
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 120),
    n=st.integers(1, 40),
    d=st.integers(1, 24),
    frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 99),
)
def test_property_gather_spmm(m, n, d, frac, seed):
    valid = int(m * frac)
    idx, seg, feats, _, _ = _edges(m, n, valid, seed, d=d)
    want = gather_spmm_ref(feats, idx, seg, n)
    _close(gather_spmm_pallas(feats, idx, seg, n, block_edges=32), want)
    _close(gather_spmm_ragged_pallas(feats, idx, seg, n, block_edges=32), want)


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 120),
    n=st.integers(1, 40),
    d=st.integers(1, 24),
    frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 99),
)
def test_property_segment_spmm_ragged(m, n, d, frac, seed):
    valid = int(m * frac)
    _, seg, _, msg, _ = _edges(m, n, valid, seed, d=d)
    want = segment_spmm_ref(msg, seg, n)
    _close(segment_spmm_ragged_pallas(msg, seg, n, block_edges=32), want)


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 120),
    n=st.integers(1, 40),
    d=st.integers(1, 24),
    frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 99),
)
def test_property_gat_softmax_aggregate(m, n, d, frac, seed):
    valid = int(m * frac)
    _, seg, _, msg, logits = _edges(m, n, valid, seed, d=d)
    want = gat_softmax_aggregate_ref(logits, msg, seg, n)
    got = gat_softmax_aggregate_pallas(logits, msg, seg, n, block_edges=32)
    # softmax-weighted sums amplify error a touch vs plain sums
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 120),
    n=st.integers(1, 40),
    frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 99),
)
def test_property_segment_max(m, n, frac, seed):
    valid = int(m * frac)
    _, seg, _, _, logits = _edges(m, n, valid, seed, d=1)
    want = segment_max_ref(logits, seg, n)
    got = segment_max_pallas(logits, seg, n, block_edges=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# dtype sweep + deterministic edge cases
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_kernels_dtypes(dtype):
    m, n, d = 200, 48, 16
    idx, seg, feats, msg, logits = _edges(m, n, 150, seed=7, d=d, dtype=dtype)
    _close(
        gather_spmm_pallas(feats, idx, seg, n), gather_spmm_ref(feats, idx, seg, n),
        dtype,
    )
    _close(
        gather_spmm_ragged_pallas(feats, idx, seg, n),
        gather_spmm_ref(feats, idx, seg, n),
        dtype,
    )
    _close(
        gat_softmax_aggregate_pallas(logits, msg, seg, n),
        gat_softmax_aggregate_ref(logits, msg, seg, n),
        dtype,
    )
    got = segment_max_pallas(logits, seg, n)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(segment_max_ref(logits, seg, n), np.float32),
    )
    assert got.dtype == dtype


def test_all_padding_tiles_produce_zeros():
    n, d = 12, 8
    idx, seg, feats, msg, logits = _edges(96, n, 0, seed=3, d=d)
    assert not np.asarray(gather_spmm_pallas(feats, idx, seg, n, block_edges=32)).any()
    assert not np.asarray(
        gather_spmm_ragged_pallas(feats, idx, seg, n, block_edges=32)
    ).any()
    assert not np.asarray(
        gat_softmax_aggregate_pallas(logits, msg, seg, n, block_edges=32)
    ).any()
    # empty segments: segment-max convention is 0, matching the oracle
    np.testing.assert_array_equal(
        np.asarray(segment_max_pallas(logits, seg, n, block_edges=32)), np.zeros(n)
    )


def test_zero_edge_input():
    n, d = 5, 4
    feats = jnp.ones((n, d), jnp.float32)
    empty_i = jnp.zeros((0,), jnp.int32)
    empty_f = jnp.zeros((0, d), jnp.float32)
    out = gather_spmm_pallas(feats, empty_i, empty_i, n)
    assert out.shape == (n, d) and not np.asarray(out).any()
    out = gat_softmax_aggregate_pallas(
        jnp.zeros((0,), jnp.float32), empty_f, empty_i, n
    )
    assert out.shape == (n, d) and not np.asarray(out).any()


def test_segment_with_no_edges_stays_zero():
    # segment 1 never appears: its row must be exactly zero, not epsilon
    seg = jnp.array([0, 0, 2, -1], jnp.int32)
    idx = jnp.array([1, 2, 0, -1], jnp.int32)
    feats = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
    out = np.asarray(gather_spmm_pallas(feats, idx, seg, 3))
    np.testing.assert_array_equal(out[1], np.zeros(4))
    np.testing.assert_allclose(out, np.asarray(gather_spmm_ref(feats, idx, seg, 3)))


# ---------------------------------------------------------------------------
# autotuner: deterministic choice, memory/artifact cache hits
# ---------------------------------------------------------------------------


@pytest.fixture(autouse=True)
def _clean_tuner():
    at.reset()
    yield
    at.reset()


def test_autotune_same_inputs_same_config(tmp_path):
    shape = (256, 64, 16)
    cfg1 = at.autotune("gather_spmm", shape, jnp.float32, cache_dir=str(tmp_path))
    assert at.stats()["measured"] == 1
    cfg2 = at.autotune("gather_spmm", shape, jnp.float32, cache_dir=str(tmp_path))
    assert cfg2 == cfg1 and at.stats()["memory_hits"] == 1
    assert at.stats()["measured"] == 1  # no re-sweep
    # the chosen config is from the fixed candidate grid
    assert cfg1 in at.CANDIDATES["gather_spmm"]
    # and ops.py can now resolve it for any shape in the same pow2 bucket
    assert at.get_tuned("gather_spmm", (200, 50, 12), jnp.float32) == cfg1


def test_autotune_artifact_roundtrip(tmp_path):
    shape = (256, 64, 16)
    cfg = at.autotune("segment_max", shape, jnp.float32, cache_dir=str(tmp_path))
    path = at.artifact_path(str(tmp_path))
    assert path.endswith(".json") and "kernel_tune_v" in path
    payload = json.loads(open(path).read())
    key = at.tuned_key("segment_max", shape, jnp.float32)
    assert payload["configs"][key] == {
        "block_rows": cfg.block_rows, "block_edges": cfg.block_edges,
    }
    at.reset(clear_stats=False)  # simulate a fresh process, artifact survives
    cfg2 = at.autotune("segment_max", shape, jnp.float32, cache_dir=str(tmp_path))
    assert cfg2 == cfg and at.stats()["artifact_hits"] == 1
    assert at.stats()["measured"] == 1  # artifact hit: no re-sweep


def test_autotune_key_buckets_pow2():
    k1 = at.tuned_key("gather_spmm", (200, 50, 12), jnp.float32)
    k2 = at.tuned_key("gather_spmm", (256, 64, 16), jnp.float32)
    assert k1 == k2 == "gather_spmm/256x64x16/float32"
    assert at.tuned_key("gather_spmm", (300, 50, 12), jnp.float32) != k1
    assert at.tuned_key("gather_spmm", (200, 50, 12), jnp.bfloat16) != k1


def test_autotune_unknown_op_raises():
    with pytest.raises(ValueError, match="unknown tuned op"):
        at.autotune("not_a_kernel", (64, 16, 8), jnp.float32)


def test_autotune_for_slice_tunes_each_shape(tmp_path):
    shapes = [
        ("segment_spmm_ragged", (128, 32, 8)),
        ("gat_softmax_aggregate", (128, 32, 8)),
    ]
    at.autotune_for_slice(shapes, jnp.float32, cache_dir=str(tmp_path))
    assert at.stats()["measured"] == 2
    for op, shape in shapes:
        assert at.get_tuned(op, shape, jnp.float32) in at.CANDIDATES[op]
