"""Optimizer, checkpointing, end-to-end trainers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    sgd_update,
)


def test_adamw_converges_quadratic():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=10_000)
    loss_fn = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(300):
        grads = jax.grad(loss_fn)(params)
        params, opt, _ = adamw_update(params, grads, opt, cfg)
    assert float(loss_fn(params)) < 1e-2


def test_clip_by_global_norm():
    grads = {"a": jnp.ones(4) * 10}
    clipped, gn = clip_by_global_norm(grads, 1.0)
    assert float(gn) == pytest.approx(20.0)
    norm = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert norm == pytest.approx(1.0, rel=1e-5)


def test_sgd_update():
    params = {"w": jnp.array([1.0])}
    grads = {"w": jnp.array([0.5])}
    new, vel = sgd_update(params, grads, None, lr=0.1)
    assert float(new["w"][0]) == pytest.approx(0.95)


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "stages": [[{"w": jnp.ones((2, 2))}], [{"w": jnp.zeros((3,))}]],
        "none": None,
    }
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, tree, step=42)
    restored, step = load_checkpoint(path, tree)
    assert step == 42
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(
        np.asarray(restored["stages"][0][0]["w"]), np.ones((2, 2))
    )
    assert restored["none"] is None


def test_lm_trainer_loss_decreases():
    from repro.models.transformer.config import ArchConfig
    from repro.train import LMTrainer

    cfg = ArchConfig(name="t", family="dense", num_layers=2, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                     dtype="float32")
    tr = LMTrainer(cfg, batch=8, seq_len=64,
                   opt=__import__("repro.train.optim", fromlist=["AdamWConfig"]).AdamWConfig(
                       lr=3e-3, warmup_steps=5, total_steps=100))
    log = tr.train(60, log_every=59)
    assert log.losses[-1] < log.losses[0] - 0.1, log.losses


def test_gnn_trainer_end_to_end(small_graph, sampling_client):
    from repro.models.gnn import GNNModel
    from repro.train import GNNTrainer
    from repro.train.optim import AdamWConfig

    g = small_graph
    # learnable labels: vertex type encoded in features
    g.labels = g.vertex_types.astype(np.int32)
    g.vertex_feats[:, :3] = 0
    g.vertex_feats[np.arange(g.num_vertices), g.labels] += 2.0
    model = GNNModel("sage", g.vertex_feats.shape[1], hidden=32, num_layers=2,
                     num_classes=3)
    ids = np.arange(g.num_vertices)
    tr = GNNTrainer(model, sampling_client, g, [8, 4], ids[:1500], batch_size=128,
                    opt=AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=100))
    tr.train(epochs=3, log_every=5)
    acc = tr.evaluate(ids[1500:], batches=3)
    assert acc > 0.6, acc  # well above 1/3 chance
