"""DET002: hash-order set iteration leaking into values."""
import numpy as np


def bad(items, other):
    out = list({x for x in items})  # expect[DET002]
    for v in set(items):  # expect[DET002]
        out.append(v)
    pairs = [(v, 1) for v in set(other)]  # expect[DET002]
    arr = np.array(set(items))  # expect[DET002]
    text = ",".join({str(x) for x in items})  # expect[DET002]
    return out, pairs, arr, text


def good(items):
    for v in sorted(set(items)):
        yield v
    # order-free reductions over sets are fine
    n = len(set(items))
    yield n, max(set(items)), np.unique(np.asarray(items))
