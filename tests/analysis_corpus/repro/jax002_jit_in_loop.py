"""JAX002: fresh jit caches built inside loops."""
import functools

import jax


def bad(fns, xs):
    outs = []
    for f in fns:
        outs.append(jax.jit(f)(xs))  # expect[JAX002]
    k = 0
    while k < len(fns):
        g = functools.partial(jax.jit, static_argnames=("n",))(fns[k])  # expect[JAX002]
        outs.append(g(xs, n=2))
        k += 1
    return outs


def good(fns, xs):
    jitted = [jax.jit(f) for f in fns]  # hoisted: one cache per fn
    return [jf(xs) for jf in jitted]


class Engine:
    def slice_fn(self, f):
        # cached-per-object pattern (the inference engine): fine
        return jax.jit(f)
