"""DET003: wall clock / OS entropy / listing order feeding values."""
import glob
import os
import time
import uuid


def bad(d):
    stamp = time.time()  # expect[DET003]
    names = os.listdir(d)  # expect[DET003]
    chunks = glob.glob(f"{d}/*.bin")  # expect[DET003]
    run_id = uuid.uuid4()  # expect[DET003]
    return stamp, names, chunks, run_id


def good(d):
    if not os.listdir(d):
        return []
    t0 = time.perf_counter()
    files = sorted(glob.glob(f"{d}/*.bin"))
    assert os.listdir(d)
    return files, len(os.listdir(d)), time.perf_counter() - t0
