"""PRJ006: multiprocessing hygiene (this file sits under a repro/
directory, so it counts as library code)."""
import multiprocessing as mp


def bad(target, worker_proc, popen):
    ctx = mp.get_context("fork")
    p1 = mp.Process(target=target)  # expect[PRJ006]
    p2 = ctx.Process(target=target, args=(1,))  # expect[PRJ006]
    p1.start()
    p2.start()
    worker_proc.join()  # expect[PRJ006]
    popen.wait()  # expect[PRJ006]
    return p1, p2


def good(target, worker_proc, popen, t, lock, cond):
    ctx = mp.get_context("fork")
    p1 = mp.Process(target=target, daemon=True)
    p2 = ctx.Process(target=target, daemon=False)  # explicit is fine too
    p1.start()
    p2.start()
    worker_proc.join(timeout=2.0)
    popen.wait(timeout=5.0)
    t.join()  # thread handle: dies with the interpreter, out of scope
    with lock:
        cond.wait()  # condition variable, not a process handle
    return p1, p2
