"""JAX003: non-hashable defaults on jit static args."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("opts",))
def bad(x, opts=[]):  # expect[JAX003]
    return x * len(opts)


@functools.partial(jax.jit, static_argnames=("shape",))
def bad_kwonly(x, *, shape={}):  # expect[JAX003]
    return x.reshape(tuple(shape))


@functools.partial(jax.jit, static_argnames=("opts",))
def good(x, opts=()):
    return x * len(opts)


@jax.jit
def no_statics(x, opts=[]):  # mutable default, but not a static arg
    return x
