"""PRJ004: unbounded blocking waits in library code (this file sits under
a repro/ directory, so it counts as library code)."""


def bad(ticket, work_q, self):
    sub = ticket.result()  # expect[PRJ004]
    item = work_q.get()  # expect[PRJ004]
    cmd = self._cmd_q.get()  # expect[PRJ004]
    return sub, item, cmd


def good(ticket, work_q, config, mapping):
    sub = ticket.result(timeout=5.0)
    deferred = ticket.result(timeout=None)  # deliberate: configured deadline
    item = work_q.get(timeout=1.0)
    value = mapping.get("key")  # dict.get: not a queue receiver
    fallback = config.get("prefetch", 2)
    return sub, deferred, item, value, fallback
