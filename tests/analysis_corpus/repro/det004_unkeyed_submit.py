"""DET004: service submits without a caller-owned RNG key (library-scoped:
this file sits under a repro/ directory on purpose)."""


def bad(service, seeds, spec):
    return service.submit(seeds, spec)  # expect[DET004]


def also_bad(submit, seeds):
    return submit(seeds)  # expect[DET004]


def good(service, seeds, spec, key, kwargs):
    a = service.submit(seeds, spec, key=key)
    b = service.submit(seeds, spec, **kwargs)  # key may ride in kwargs
    c = service.submit()  # no request payload: not a sample submission
    return a, b, c
