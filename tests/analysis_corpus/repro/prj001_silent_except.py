"""PRJ001: broad excepts with silent bodies."""
import logging

_log = logging.getLogger(__name__)


def bad(risky):
    try:
        risky()
    except Exception:  # expect[PRJ001]
        pass
    try:
        risky()
    except (ValueError, BaseException):  # expect[PRJ001]
        ...


def good(risky):
    try:
        risky()
    except (OSError, ValueError) as exc:  # narrow: fine even if silent-ish
        _log.debug("risky failed: %s", exc)
    try:
        risky()
    except Exception:
        _log.warning("risky failed")  # broad but not silent


class Holder:
    def __del__(self):
        try:
            self.close()
        except Exception:  # finalizers may not raise
            pass
