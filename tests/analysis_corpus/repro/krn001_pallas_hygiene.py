"""KRN001: pallas_call interpret plumbing + *_ref oracle coverage."""
import jax
from jax.experimental import pallas as pl

INTERPRET = True


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def gather_spmm_pallas(x, *, interpret: bool = True):  # oracle exists: fine
    return pl.pallas_call(_kernel, out_shape=x, interpret=True)(x)  # expect[KRN001]


def segment_max_pallas(x, *, interpret: bool = True):  # oracle exists: fine
    return pl.pallas_call(_kernel, out_shape=x)(x)  # expect[KRN001]


def ssd_scan_pallas(x):  # oracle exists, but no interpret parameter
    return pl.pallas_call(_kernel, out_shape=x, interpret=INTERPRET)(x)  # expect[KRN001]


def fancy_scan_pallas(x, *, interpret: bool = True):  # expect[KRN001]
    # interpret is plumbed correctly, but repro.kernels.ref exports no
    # fancy_scan_ref oracle to allclose this kernel against
    return pl.pallas_call(_kernel, out_shape=x, interpret=interpret)(x)


MODULE_SCOPE = pl.pallas_call(_kernel, out_shape=jax.ShapeDtypeStruct((8,), "float32"))  # expect[KRN001]


def segment_spmm_pallas(x, *, interpret: bool = True):  # clean: plumbed + oracle
    return pl.pallas_call(_kernel, out_shape=x, interpret=interpret)(x)


def _launch(kernel, x, interpret):  # clean: private helper plumbs interpret
    return pl.pallas_call(kernel, out_shape=x, interpret=interpret)(x)
