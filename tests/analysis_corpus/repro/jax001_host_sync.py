"""JAX001: host syncs / tracer concretization inside jit-traced code."""
import functools

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def bad_item(x):
    return x.sum().item()  # expect[JAX001]


@functools.partial(jax.jit, static_argnames=("n",))
def bad_np(x, n):
    y = np.asarray(x)  # expect[JAX001]
    return y * n


@jax.jit
def bad_float(x):
    return float(x)  # expect[JAX001]


def _slice(h):
    return np.square(h)  # expect[JAX001]


def layer(h):
    return h * 2


# the engine jits whatever hangs off ``.jax`` — the project convention
layer.jax = _slice


@jax.jit
def good(x, y):
    scale = float(x.shape[0])  # static metadata: fine
    return jnp.dot(x, y) / scale, np.float32(0.5)


def host_side(x):
    # not jit-traced: host round-trips are allowed
    return float(np.asarray(x).sum())
