"""JAX004: jit-input padding to raw data-dependent lengths."""
import jax.numpy as jnp

from repro.utils import pad_to, round_up


def bad(x, batch):
    a = pad_to(x, x.shape[0])  # expect[JAX004]
    b = pad_to(x, len(batch))  # expect[JAX004]
    n = x.shape[0]
    c = jnp.pad(x, ((0, n - x.shape[0]), (0, 0)))  # expect[JAX004]
    return a, b, c


def good(x, batch, edge_quantum):
    a = pad_to(x, round_up(x.shape[0], 64))
    b = pad_to(x, edge_quantum)
    m = x.shape[0]
    m_pad = -(-m // 128) * 128  # ceil-style floor-div: bucketed
    c = jnp.pad(x, ((0, m_pad - m), (0, 0)))
    d = jnp.pad(x, ((0, 3), (0, 0)))
    return a, b, c, d
