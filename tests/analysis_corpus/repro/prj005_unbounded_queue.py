"""PRJ005: unbounded request-queue constructions in library code (this
file sits under a repro/ directory, so it counts as library code)."""
import collections
import multiprocessing
import queue
from collections import deque


class Serverish:
    def __init__(self, depth):
        self.request_q = queue.Queue()  # expect[PRJ005]
        self.retry_queue = queue.PriorityQueue()  # expect[PRJ005]
        self.work_q = multiprocessing.Queue()  # expect[PRJ005]
        self.event_q = queue.SimpleQueue()  # expect[PRJ005]
        self.reply_queue = collections.deque()  # expect[PRJ005]
        self._q = deque()  # expect[PRJ005]
        # bounded or not-a-queue: all fine
        self.bounded_q = queue.Queue(maxsize=depth)
        self.sized_q = queue.Queue(depth)
        self.ring_queue = deque(maxlen=depth)
        self.visit_stack = deque()  # scratch structure, not a queue name
        window: deque = deque([0], depth)  # positional maxlen
        self.window = window
