"""PRJ003: literal registry keys that do not exist in the live registries."""
import pytest

from repro.api.backends import CACHE_POLICIES, PARTITIONERS, REORDERS


def bad():
    pol = CACHE_POLICIES.get("nope")  # expect[PRJ003]
    part = PARTITIONERS.get("metis-5000")  # expect[PRJ003]
    return pol, part


class GLISPConfig:  # drifted copy: defaults must resolve
    partitioner: str = "adadne"
    cache_policy: str = "missing-policy"  # expect[PRJ003]


def good():
    pol = CACHE_POLICIES.get("fifo")
    ro = REORDERS.get("pds")
    with pytest.raises(ValueError):
        CACHE_POLICIES.get("nope")  # asserting the error path: fine
    return pol, ro
