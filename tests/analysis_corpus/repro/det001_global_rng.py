"""DET001: process-global RNG state."""
import random

import numpy as np


def bad(n):
    x = np.random.rand(n)  # expect[DET001]
    np.random.seed(0)  # expect[DET001]
    random.shuffle(x)  # expect[DET001]
    return x + random.random()  # expect[DET001]


def good(n, seed):
    rng = np.random.default_rng(seed)
    ss = np.random.SeedSequence(seed)
    py = random.Random(seed)
    return rng.random(n), ss.spawn(1), py.random()
