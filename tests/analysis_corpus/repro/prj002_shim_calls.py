"""PRJ002: deprecated shims called from library code (this file sits under
a repro/ directory, and is not one of the shim-defining modules)."""
from repro.core.inference import ChunkedEmbeddingStore, TwoLevelCache
from repro.core.partition import adadne


def bad(backend, seeds, g):
    cache = TwoLevelCache(4, 2)  # expect[PRJ002]
    store = ChunkedEmbeddingStore("/tmp/x", 8, 4, 2)  # expect[PRJ002]
    ep = adadne(g, 4, seed=0)  # expect[PRJ002]
    sub = backend.sample(seeds)  # expect[PRJ002]
    return cache, store, ep, sub


def good(backend, seeds, spec, key, PARTITIONERS):
    ep = PARTITIONERS.get("adadne").partition(seeds, 4, seed=0)
    ticket = backend.submit(seeds, spec, key=key)
    return ep, ticket.result(timeout=None)
