import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_graph():
    from repro.graph import power_law_graph

    return power_law_graph(2000, avg_degree=8, seed=7, feat_dim=16, num_classes=4)


@pytest.fixture(scope="session")
def partitioned(small_graph):
    from repro.core.partition import adadne
    from repro.graph import build_partitions

    ep = adadne(small_graph, 4, seed=0)
    parts = build_partitions(small_graph, ep, 4)
    return ep, parts


@pytest.fixture(scope="session")
def sampling_client(small_graph, partitioned):
    from repro.core.sampling import GatherApplyClient, SamplingServer, VertexRouter

    ep, parts = partitioned
    return GatherApplyClient(
        [SamplingServer(p, seed=0) for p in parts],
        VertexRouter(small_graph, ep, 4),
        seed=0,
    )
