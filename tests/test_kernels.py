"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape/dtype
sweeps + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # minimal envs: deterministic fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ref import attention_ref, segment_spmm_ref, ssd_scan_ref
from repro.kernels.segment_spmm import segment_spmm_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


# ---------------------------------------------------------------------------
# segment spmm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,d,n", [(64, 32, 16), (200, 64, 100), (513, 128, 257)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_spmm_shapes(m, d, n, dtype):
    rng = np.random.default_rng(m)
    msg = jnp.asarray(rng.standard_normal((m, d)), dtype=dtype)
    seg = jnp.asarray(np.sort(rng.integers(0, n, m)).astype(np.int32))
    out_k = segment_spmm_pallas(msg, seg, n, block_rows=64, block_edges=64)
    out_r = segment_spmm_ref(msg, seg, n)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        rtol=tol, atol=tol * 10,
    )


def test_segment_spmm_padding_ignored():
    msg = jnp.ones((8, 4), jnp.float32)
    seg = jnp.array([0, 0, 1, -1, -1, 2, 2, 2], jnp.int32)
    out = segment_spmm_pallas(msg, seg, 3, block_rows=8, block_edges=8)
    np.testing.assert_allclose(np.asarray(out)[:, 0], [2, 1, 3])


def test_segment_spmm_unsorted_segments():
    rng = np.random.default_rng(0)
    msg = jnp.asarray(rng.standard_normal((100, 16)).astype(np.float32))
    seg = jnp.asarray(rng.integers(0, 20, 100).astype(np.int32))  # unsorted
    out_k = segment_spmm_pallas(msg, seg, 20, block_rows=32, block_edges=32)
    out_r = segment_spmm_ref(msg, seg, 20)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 150),
    d=st.integers(1, 40),
    n=st.integers(1, 50),
    seed=st.integers(0, 99),
)
def test_property_segment_spmm(m, d, n, seed):
    rng = np.random.default_rng(seed)
    msg = rng.standard_normal((m, d)).astype(np.float32)
    seg = rng.integers(-1, n, m).astype(np.int32)
    out_k = np.asarray(segment_spmm_pallas(jnp.asarray(msg), jnp.asarray(seg), n,
                                           block_rows=32, block_edges=32))
    # numpy oracle
    want = np.zeros((n, d), np.float32)
    for i in range(m):
        if seg[i] >= 0:
            want[seg[i]] += msg[i]
    np.testing.assert_allclose(out_k, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sq,skv,d", [(64, 64, 32), (100, 100, 64), (1, 200, 32)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 37), (False, 0)])
def test_flash_attention(sq, skv, d, causal, window):
    rng = np.random.default_rng(sq + d)
    q = jnp.asarray(rng.standard_normal((sq, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((skv, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((skv, d)).astype(np.float32))
    off = skv - sq if sq < skv else 0
    out_k = flash_attention_pallas(
        q, k, v, causal=causal, window=window, kv_offset=off,
        block_q=32, block_kv=32,
    )
    out_r = attention_ref(q, k, v, causal=causal, window=window, kv_offset=off)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_model_blockwise():
    """Kernel == the model's pure-jnp blockwise attention path."""
    from repro.models.transformer.layers import _blockwise_attention

    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 96, 2, 32)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 96, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, 96, 2, 32)).astype(np.float32))
    blockwise = _blockwise_attention(q, k, v, causal=True, window=0, q_offset=0)
    for h in range(2):
        out_k = flash_attention_pallas(
            q[0, :, h], k[0, :, h], v[0, :, h], causal=True,
            block_q=32, block_kv=32,
        )
        np.testing.assert_allclose(
            np.asarray(out_k), np.asarray(blockwise[0, :, h]), rtol=2e-5, atol=2e-5
        )


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,P,N,chunk", [(64, 16, 8, 16), (100, 32, 16, 32), (33, 8, 4, 16)])
def test_ssd_scan(S, P, N, chunk):
    rng = np.random.default_rng(S)
    x = jnp.asarray(rng.standard_normal((S, P)).astype(np.float32))
    dt = jnp.asarray((rng.random(S) * 0.5 + 0.01).astype(np.float32))
    A = -0.7
    B = jnp.asarray(rng.standard_normal((S, N)).astype(np.float32))
    C = jnp.asarray(rng.standard_normal((S, N)).astype(np.float32))
    y_k, state = ssd_scan_pallas(x, A * dt, dt, B, C, chunk=chunk)
    y_r = ssd_scan_ref(
        x[:, None, :], dt[:, None], jnp.array([A]), B[:, None, :], C[:, None, :]
    )[:, 0, :]
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=2e-4, atol=2e-4)


def test_ssd_chunked_jnp_matches_ref():
    """The model's chunked SSD == sequential recurrence oracle (multi-head,
    grouped B/C)."""
    from repro.models.transformer.ssm import ssd_chunked_jnp

    rng = np.random.default_rng(3)
    Bz, S, H, P, G, N = 2, 48, 4, 8, 2, 6
    x = jnp.asarray(rng.standard_normal((Bz, S, H, P)).astype(np.float32))
    dt = jnp.asarray((rng.random((Bz, S, H)) * 0.5 + 0.01).astype(np.float32))
    A = jnp.asarray(-rng.random(H).astype(np.float32) - 0.1)
    Bg = jnp.asarray(rng.standard_normal((Bz, S, G, N)).astype(np.float32))
    Cg = jnp.asarray(rng.standard_normal((Bz, S, G, N)).astype(np.float32))
    a = dt * A[None, None, :]
    y, state = ssd_chunked_jnp(x, a, dt, Bg, Cg, chunk=16)
    for b in range(Bz):
        y_ref = ssd_scan_ref(x[b], dt[b], A, Bg[b], Cg[b])
        np.testing.assert_allclose(np.asarray(y[b]), np.asarray(y_ref), rtol=3e-4, atol=3e-4)
