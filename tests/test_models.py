"""Transformer + GNN model correctness: family forwards, decode==train
consistency (fp32), rolling-window decode, MoE behavior, GNN gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer.config import ArchConfig, MoEConfig, SSMConfig
from repro.models.transformer.model import (
    forward,
    init_cache,
    init_params,
    lm_loss,
    param_count,
    stage_plan,
)

FP32 = dict(dtype="float32")

FAMILY_CONFIGS = {
    "dense-gqa": ArchConfig(name="d", family="dense", num_layers=3, d_model=64,
                            num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, **FP32),
    "mqa-geglu": ArchConfig(name="m", family="dense", num_layers=2, d_model=64,
                            num_heads=4, num_kv_heads=1, head_dim=32, d_ff=128,
                            vocab_size=256, activation="geglu", **FP32),
    "swa": ArchConfig(name="s", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                      window=8, **FP32),
    "mla-moe": ArchConfig(name="mm", family="moe", num_layers=2, d_model=64,
                          num_heads=4, num_kv_heads=4, head_dim=16, d_ff=0,
                          vocab_size=256, kv_lora_rank=32, rope_head_dim=16,
                          moe=MoEConfig(num_experts=4, top_k=2, num_shared=1,
                                        expert_d_ff=32, capacity_factor=8.0), **FP32),
    "ssm": ArchConfig(name="ss", family="ssm", num_layers=2, d_model=64,
                      num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=256,
                      head_dim=1, pattern=("ssm",),
                      ssm=SSMConfig(state_dim=16, head_dim=16, num_groups=1,
                                    expand=2, chunk=8), **FP32),
    "hybrid": ArchConfig(name="h", family="hybrid", num_layers=5, d_model=64,
                         num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                         pattern=("rglru", "rglru", "local_attn"),
                         local_window=16, **FP32),
    "embeddings": ArchConfig(name="e", family="vlm", num_layers=2, d_model=64,
                             num_heads=4, num_kv_heads=2, d_ff=128,
                             vocab_size=256, input_mode="embeddings", **FP32),
}


def _inputs(cfg, B, S, key):
    if cfg.input_mode == "embeddings":
        return jax.random.normal(key, (B, S, cfg.d_model))
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size)


@pytest.mark.parametrize("name", list(FAMILY_CONFIGS))
def test_forward_and_decode_consistency(name):
    cfg = FAMILY_CONFIGS[name]
    B, S = 2, 32
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    inp = _inputs(cfg, B, S, key)
    logits, aux, _ = forward(params, cfg, inp)
    assert logits.shape == (B, S, cfg.padded_vocab_size)
    assert not jnp.isnan(logits).any()
    # prefill S-1 then decode 1 == train logits at last position
    cache = init_cache(cfg, B, S)
    _, _, cache = forward(params, cfg, inp[:, : S - 1], cache, 0)
    ld, _, _ = forward(params, cfg, inp[:, S - 1 :], cache, S - 1)
    ref = logits[:, -1, : cfg.vocab_size]
    got = ld[:, 0, : cfg.vocab_size]
    rel = float(jnp.abs(got - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 1e-4, rel


@pytest.mark.parametrize("name", ["dense-gqa", "ssm", "hybrid"])
def test_stepwise_decode_matches_train(name):
    """Decode the whole sequence token by token; logits must match the
    teacher-forced forward at every position."""
    cfg = FAMILY_CONFIGS[name]
    B, S = 1, 16
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    inp = _inputs(cfg, B, S, key)
    ref, _, _ = forward(params, cfg, inp)
    cache = init_cache(cfg, B, S)
    for t in range(S):
        sl = inp[:, t : t + 1]
        lg, _, cache = forward(params, cfg, sl, cache, t)
        rel = float(
            jnp.abs(lg[:, 0, : cfg.vocab_size] - ref[:, t, : cfg.vocab_size]).max()
            / (jnp.abs(ref[:, t, : cfg.vocab_size]).max() + 1e-9)
        )
        assert rel < 1e-4, (t, rel)


def test_rolling_window_cache_decode():
    """A window-sized rolling cache reproduces full-cache SWA decode."""
    cfg = FAMILY_CONFIGS["swa"]  # window=8
    B, S = 1, 24
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    inp = _inputs(cfg, B, S, key)
    ref, _, _ = forward(params, cfg, inp)  # train path applies window mask
    # decode with cache capacity == window (rolling)
    cache = init_cache(cfg, B, S)  # cache_len_for caps at window=8
    from repro.models.transformer.model import cache_len_for

    assert cache_len_for(cfg, "attn", S) == 8
    for t in range(S):
        lg, _, cache = forward(params, cfg, inp[:, t : t + 1], cache, t)
        rel = float(
            jnp.abs(lg[:, 0, : cfg.vocab_size] - ref[:, t, : cfg.vocab_size]).max()
            / (jnp.abs(ref[:, t, : cfg.vocab_size]).max() + 1e-9)
        )
        assert rel < 1e-4, (t, rel)


def test_moe_aux_loss_and_capacity():
    cfg = FAMILY_CONFIGS["mla-moe"]
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    inp = _inputs(cfg, 2, 16, key)
    _, aux, _ = forward(params, cfg, inp)
    assert float(aux) > 0.0  # load-balance loss active


def test_stage_plan_hybrid():
    cfg = FAMILY_CONFIGS["hybrid"]  # 5 layers, period 3
    plan = stage_plan(cfg)
    assert plan == [(("rglru", "rglru", "local_attn"), 1), (("rglru", "rglru"), 1)]
    total = sum(len(k) * r for k, r in plan)
    assert total == cfg.num_layers


def test_lm_loss_grads_finite():
    cfg = FAMILY_CONFIGS["dense-gqa"]
    key = jax.random.PRNGKey(4)
    params = init_params(cfg, key)
    inp = _inputs(cfg, 2, 16, key)
    (loss, _), grads = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, inp, inp), has_aux=True
    )(params)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert jnp.isfinite(g).all()


def test_unroll_equals_scan():
    cfg = FAMILY_CONFIGS["dense-gqa"]
    key = jax.random.PRNGKey(5)
    params = init_params(cfg, key)
    inp = _inputs(cfg, 2, 16, key)
    a, _, _ = forward(params, cfg, inp, unroll=False)
    b, _, _ = forward(params, cfg, inp, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_param_count_matches_analytic():
    for name in ("dense-gqa", "mqa-geglu", "ssm"):
        cfg = FAMILY_CONFIGS[name]
        params = init_params(cfg, jax.random.PRNGKey(0))
        analytic = cfg.num_params()
        actual = param_count(params)
        pad = (cfg.padded_vocab_size - cfg.vocab_size) * cfg.d_model
        assert abs(actual - pad - analytic) / analytic < 0.05, (name, actual, analytic)


def test_gnn_seg_ops_honor_use_kernel():
    """GAT/HGT attention softmax and degree counts route through the Pallas
    segment-SpMM when use_kernel is set, matching the jnp reference path."""
    from repro.models.gnn import GNNModel

    rng = np.random.default_rng(0)
    n, e, d = 32, 96, 8
    hs = rng.standard_normal((n, d)).astype(np.float32)
    hn = rng.standard_normal((e, d)).astype(np.float32)
    seg = np.sort(rng.integers(0, n, e)).astype(np.int32)
    et = rng.integers(0, 4, e).astype(np.int32)
    for kind in ("gcn", "sage", "gat", "hgt"):
        model = GNNModel(kind, d, hidden=d, num_layers=1, num_heads=2)
        params = model.init(jax.random.PRNGKey(1))
        ref = model.embed_layer_fn(params, 0, use_kernel=False)(0, hs, hn, seg, et)
        ker = model.embed_layer_fn(params, 0, use_kernel=True)(0, hs, hn, seg, et)
        np.testing.assert_allclose(ref, ker, rtol=1e-4, atol=1e-5)
