"""Minimal stand-in for ``hypothesis`` so tier-1 collection never breaks.

Covers exactly the surface the test suite uses — ``@given`` with keyword
strategies, ``@settings(max_examples=..., deadline=...)`` and
``strategies.integers/floats/booleans`` — by running each property test over
``max_examples`` deterministic pseudo-random draws (seeded from the test
name, so failures reproduce).  Install the real package from
requirements-dev.txt for actual shrinking/coverage; this shim only keeps the
suite runnable in minimal environments.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies"]

_DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def sample(self, rng: np.random.Generator):
        return self._draw(rng)


class strategies:  # noqa: N801 - mirrors the hypothesis module name
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0) -> _Strategy:
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value))
        )

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(options) -> _Strategy:
        opts = list(options)
        return _Strategy(lambda rng: opts[int(rng.integers(0, len(opts)))])


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings may be applied above @given: it then annotates this
            # wrapper, so read the attribute off the wrapper at call time
            n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode("utf-8"))
            )
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strats.items()}
                fn(*args, **drawn, **kwargs)

        wrapper._max_examples = getattr(
            fn, "_max_examples", _DEFAULT_MAX_EXAMPLES
        )
        # pytest must not see the strategy-supplied params as fixtures
        sig = inspect.signature(fn)
        remaining = [p for name, p in sig.parameters.items() if name not in strats]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        del wrapper.__wrapped__  # stop inspect from following to fn
        return wrapper

    return deco
