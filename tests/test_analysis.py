"""repro.analysis: the glint static analyzer and its runtime companion.

Four layers of coverage:

1. **Corpus exactness** — every file in ``tests/analysis_corpus/repro/``
   annotates its expected findings inline (``# expect[DET001]``); the
   engine must produce *exactly* that set of (line, rule) pairs, so a
   missed finding and a false positive both fail.
2. **Engine mechanics** — suppression pragmas (trailing, standalone-line,
   justification enforcement via E002), rule selection, skip markers,
   parse-error reporting, reporters and the CLI gate's exit codes.
3. **Self-gate** — the analyzer runs clean over this repository (the same
   invocation CI gates on).
4. **Runtime guard** — ``recompile_guard`` arithmetic over a fake engine
   (the real-engine regression lives in tests/test_inference.py).
"""
import json
import re
from pathlib import Path

import pytest

from repro.analysis import (
    PARSE_ERROR_ID,
    PRAGMA_REASON_ID,
    RULES,
    RecompileError,
    active_rules,
    check_source,
    check_file,
    iter_python_files,
    recompile_guard,
    render_json,
    render_rule_catalog,
    render_text,
    run_checks,
)
from repro.analysis.__main__ import main

REPO = Path(__file__).resolve().parents[1]
CORPUS_DIR = REPO / "tests" / "analysis_corpus"
CORPUS = sorted((CORPUS_DIR / "repro").glob("*.py"))

_EXPECT = re.compile(r"#\s*expect\[([A-Z0-9,]+)\]")


def _expected_findings(source: str) -> set:
    out = set()
    for lineno, line in enumerate(source.splitlines(), 1):
        m = _EXPECT.search(line)
        if m:
            for rid in m.group(1).split(","):
                out.add((lineno, rid))
    return out


# ---------------------------------------------------------------------------
# corpus: exact (line, rule) agreement per file
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("path", CORPUS, ids=lambda p: p.stem)
def test_corpus_exact(path):
    source = path.read_text()
    expected = _expected_findings(source)
    assert expected, f"{path.name} has no expect[] annotations"
    findings, suppressed = check_file(path)
    assert not suppressed, "corpus files must not carry suppressions"
    got = {(f.line, f.rule) for f in findings}
    missed = expected - got
    false_pos = got - expected
    assert got == expected, (
        f"{path.name}: missed={sorted(missed)} false_positives="
        f"{sorted(false_pos)}\n" + "\n".join(f.render() for f in findings)
    )


def test_every_rule_has_a_fixture():
    """Each registered rule is exercised by at least one known-bad line."""
    covered = set()
    for path in CORPUS:
        covered |= {rid for _, rid in _expected_findings(path.read_text())}
    registered = {r.id for r in active_rules()}
    assert covered == registered, (
        f"rules without corpus fixtures: {sorted(registered - covered)}; "
        f"fixtures for unknown rules: {sorted(covered - registered)}"
    )


def test_rule_catalog_metadata():
    rules = active_rules()
    assert len(rules) >= 8
    ids = [r.id for r in rules]
    assert len(ids) == len(set(ids))
    for r in rules:
        assert r.family in ("determinism", "jax", "kernels", "project")
        assert r.rationale.strip()
        assert re.fullmatch(r"[A-Z]{3}\d{3}", r.id)


# ---------------------------------------------------------------------------
# engine mechanics
# ---------------------------------------------------------------------------

_BAD = "import numpy as np\nx = np.random.rand(3)\n"


def test_trailing_suppression_with_reason():
    src = _BAD.replace(
        "rand(3)", "rand(3)  # glint: disable=DET001 -- demo snippet"
    )
    findings, suppressed = check_source(src)
    assert not findings
    assert [f.rule for f in suppressed] == ["DET001"]


def test_suppression_without_reason_is_flagged():
    src = _BAD.replace("rand(3)", "rand(3)  # glint: disable=DET001")
    findings, _ = check_source(src)
    assert [f.rule for f in findings] == [PRAGMA_REASON_ID]


def test_standalone_pragma_covers_next_code_line():
    src = (
        "import numpy as np\n"
        "# glint: disable=DET001 -- standalone pragma, multi-line reason\n"
        "# continues here\n"
        "x = np.random.rand(3)\n"
    )
    findings, suppressed = check_source(src)
    assert not findings
    assert [f.rule for f in suppressed] == ["DET001"]


def test_bare_disable_suppresses_all_rules():
    src = (
        "import numpy as np\n"
        "x = np.random.rand(3)  # glint: disable -- kitchen sink\n"
    )
    findings, suppressed = check_source(src)
    assert not findings and len(suppressed) == 1


def test_pragma_for_other_rule_does_not_suppress():
    src = _BAD.replace("rand(3)", "rand(3)  # glint: disable=JAX001 -- wrong id")
    findings, _ = check_source(src)
    assert [f.rule for f in findings] == ["DET001"]


def test_select_and_ignore_filters():
    two_bugs = "import numpy as np\nimport time\nx = np.random.rand(int(time.time()))\n"
    all_rules = {f.rule for f in check_source(two_bugs)[0]}
    assert all_rules == {"DET001", "DET003"}
    only_det1 = check_source(two_bugs, rules=active_rules(select=["DET001"]))[0]
    assert {f.rule for f in only_det1} == {"DET001"}
    by_family = check_source(two_bugs, rules=active_rules(ignore=["determinism"]))[0]
    assert not by_family


def test_parse_error_is_reported_not_raised():
    findings, _ = check_source("def broken(:\n")
    assert [f.rule for f in findings] == [PARSE_ERROR_ID]


def test_skip_marker_prunes_directory_scans():
    files = iter_python_files([CORPUS_DIR])
    assert files == [], "corpus must be invisible to directory scans"
    # but explicitly named files are always checked
    assert iter_python_files([CORPUS[0]]) == [CORPUS[0]]


def test_import_alias_resolution():
    src = "from numpy import random as nr\nx = nr.rand(3)\n"
    findings, _ = check_source(src)
    assert [f.rule for f in findings] == ["DET001"]


# ---------------------------------------------------------------------------
# reporters + CLI
# ---------------------------------------------------------------------------


def test_reporters_roundtrip():
    report = run_checks([CORPUS[0]])
    assert not report.ok and report.files_checked == 1
    text = render_text(report)
    assert f"{report.findings[0].line}" in text and "finding(s)" in text
    data = json.loads(render_json(report))
    assert data["ok"] is False
    assert data["counts"] and data["findings"]
    assert {f["rule"] for f in data["findings"]} <= set(data["rules"])
    assert "DET001" in render_rule_catalog()


def test_cli_gate_exit_codes(tmp_path, capsys):
    bad = CORPUS[0]
    assert main([str(bad)]) == 1
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    assert main([str(clean)]) == 0
    assert main(["--list-rules"]) == 0
    out = tmp_path / "report.json"
    assert main([str(bad), "--format", "json", "--out", str(out)]) == 1
    data = json.loads(out.read_text())
    assert data["ok"] is False and data["findings"]
    capsys.readouterr()


def test_cli_select_ignore(tmp_path):
    bad = CORPUS[0]  # det001 fixture
    assert main([str(bad), "--ignore", "DET001"]) == 0
    assert main([str(bad), "--select", "jax"]) == 0
    assert main([str(bad), "--select", "determinism"]) == 1


# ---------------------------------------------------------------------------
# the self-gate: this repository lints clean (CI runs the same invocation)
# ---------------------------------------------------------------------------


def test_repository_is_glint_clean():
    report = run_checks(
        [REPO / "src", REPO / "tests", REPO / "benchmarks", REPO / "examples"]
    )
    assert report.files_checked > 50
    assert report.ok, "tree has unsuppressed findings:\n" + "\n".join(
        f.render() for f in report.findings
    )


# ---------------------------------------------------------------------------
# config <-> registry cross-validation (the live counterpart of PRJ003)
# ---------------------------------------------------------------------------


def test_config_accepts_exactly_the_registered_names():
    from repro.api import backends
    from repro.api.config import GLISPConfig

    field_regs = {
        "partitioner": backends.PARTITIONERS,
        "sampler": backends.SAMPLERS,
        "reorder": backends.REORDERS,
        "cache_policy": backends.CACHE_POLICIES,
    }
    defaults = GLISPConfig()
    for fname, reg in field_regs.items():
        assert getattr(defaults, fname) in reg  # default is registered
        for name in reg.names():  # every registered name validates
            defaults.replace(**{fname: name}).validate()
        with pytest.raises(ValueError):
            defaults.replace(**{fname: "not-a-registered-name"}).validate()
    for tier in defaults.storage_tiers:
        assert tier in backends.STORAGE_TIERS
    for name in backends.STORAGE_TIERS.names():
        defaults.replace(storage_tiers=(name,)).validate()
    with pytest.raises(ValueError):
        defaults.replace(storage_tiers=("not-a-tier",)).validate()


# ---------------------------------------------------------------------------
# recompile_guard arithmetic (fake engine; real engine in test_inference.py)
# ---------------------------------------------------------------------------


class _FakeEngine:
    def __init__(self):
        self.traces = 0
        self.shapes = set()

    def jit_trace_count(self):
        return self.traces

    def shape_count(self):
        return len(self.shapes)

    def run_batch(self, key):
        if key not in self.shapes:  # jit cache semantics: miss -> trace
            self.shapes.add(key)
            self.traces += 1


class _FakeSystem:
    def __init__(self):
        self.infer_engine = None


def test_recompile_guard_ok_within_bound():
    eng = _FakeEngine()
    with recompile_guard(eng) as rec:
        eng.run_batch((0, 64, 256))
        eng.run_batch((1, 64, 256))
    assert (rec.compiles, rec.new_shapes, rec.bound) == (2, 2, 2)


def test_recompile_guard_raises_on_shape_leak():
    eng = _FakeEngine()
    with pytest.raises(RecompileError, match="2 jit slice"):
        with recompile_guard(eng):
            eng.run_batch((0, 64, 256))
            eng.traces += 1  # a retrace with no new shape: the leak
    # extra= widens the bound for intentional recompiles
    eng2 = _FakeEngine()
    with recompile_guard(eng2, extra=1):
        eng2.run_batch((0, 64, 256))
        eng2.traces += 1


def test_recompile_guard_only_counts_the_guarded_region():
    eng = _FakeEngine()
    eng.run_batch((0, 64, 256))  # before the guard: not counted
    with recompile_guard(eng) as rec:
        eng.run_batch((0, 64, 256))  # cache hit: no trace
        eng.run_batch((1, 64, 256))  # one new shape, one compile
    assert (rec.compiles, rec.new_shapes) == (1, 1)


def test_recompile_guard_accepts_system_with_late_engine():
    sys_like = _FakeSystem()
    with recompile_guard(sys_like) as rec:
        sys_like.infer_engine = eng = _FakeEngine()  # built mid-guard
        eng.run_batch((0, 64, 256))
    assert (rec.compiles, rec.new_shapes) == (1, 1)
    with recompile_guard(None) as rec0:  # no engine at all: a no-op guard
        pass
    assert rec0.compiles == 0
