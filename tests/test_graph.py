"""Graph substrate: partition structure invariants, queries, persistence."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # minimal envs: deterministic fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.partition import random_edge_partition
from repro.graph import GraphPartition, build_partitions, power_law_graph
from repro.graph.graph import HeteroGraph
from repro.graph.metrics import metrics_from_edge_assignment
from repro.graph.reorder import reorder_permutation


def test_partition_edge_conservation(small_graph, partitioned):
    ep, parts = partitioned
    assert sum(p.num_edges for p in parts) == small_graph.num_edges


def test_global_local_roundtrip(partitioned):
    _, parts = partitioned
    for p in parts:
        lids = np.arange(p.num_vertices)
        gids = p.local_to_global(lids)
        assert (p.global_to_local(gids) == lids).all()
        # missing ids return -1
        missing = np.array([10**12])
        assert p.global_to_local(missing)[0] == -1


def test_partition_neighbors_match_graph(small_graph, partitioned):
    """Union of per-partition out-neighbors == true out-neighbors."""
    ep, parts = partitioned
    rng = np.random.default_rng(0)
    for v in rng.choice(small_graph.num_vertices, 20, replace=False):
        true_nbrs = sorted(small_graph.neighbors(int(v), "out").tolist())
        got = []
        for p in parts:
            lid = p.global_to_local(np.array([v]))[0]
            if lid < 0:
                continue
            nbrs, _ = p.out_neighbors(int(lid))
            got.extend(p.local_to_global(nbrs).tolist())
        assert sorted(got) == true_nbrs


def test_edge_type_query(partitioned, small_graph):
    """edge_type_of (O(log) aggregated index) matches a direct recompute."""
    _, parts = partitioned
    p = parts[0]
    n = min(500, p.num_edges)
    et = p.edge_type_of(np.arange(n))
    # recompute: for each vertex the CSR slice is sorted by type with counts
    # in the aggregated index; check types are sorted within each vertex
    for lid in range(min(50, p.num_vertices)):
        s, e = p.out_indptr[lid], p.out_indptr[lid + 1]
        if e - s < 2 or e > n:
            continue
        tv = et[s:e]
        assert (np.diff(tv) >= 0).all()


def test_etype_filtered_neighbors(partitioned):
    _, parts = partitioned
    p = parts[0]
    for lid in range(min(30, p.num_vertices)):
        all_nbrs, all_eids = p.out_neighbors(lid)
        per_type = []
        ts, te = p.out_et_indptr[lid], p.out_et_indptr[lid + 1]
        for t in p.out_et_types[ts:te]:
            nbrs, eids = p.out_neighbors(lid, etype=int(t))
            per_type.extend(nbrs.tolist())
        assert sorted(per_type) == sorted(all_nbrs.tolist())


def test_save_load_roundtrip(tmp_path, partitioned):
    _, parts = partitioned
    p = parts[1]
    p.save(str(tmp_path / "p1"))
    q = GraphPartition.load(str(tmp_path / "p1"))
    for f in ("global_id", "out_indptr", "out_dst", "in_src", "partition_bits"):
        assert (getattr(p, f) == getattr(q, f)).all()


def test_memory_accounting(partitioned):
    _, parts = partitioned
    for p in parts:
        assert p.memory_bytes() > 0
        assert p.memory_bytes() < 50 * (p.num_edges + p.num_vertices) * 8


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(20, 200),
    e=st.integers(30, 400),
    parts=st.integers(2, 6),
    seed=st.integers(0, 1000),
)
def test_property_partition_invariants(n, e, parts, seed):
    """Any vertex-cut edge assignment yields a consistent structure."""
    rng = np.random.default_rng(seed)
    g = HeteroGraph(
        num_vertices=n,
        src=rng.integers(0, n, e),
        dst=rng.integers(0, n, e),
        edge_types=rng.integers(0, 3, e).astype(np.int16),
        vertex_types=rng.integers(0, 2, n).astype(np.int16),
        edge_weights=rng.random(e).astype(np.float32),
    )
    ep = random_edge_partition(g, parts, seed)
    built = build_partitions(g, ep, parts)
    assert sum(p.num_edges for p in built) == e
    m = metrics_from_edge_assignment(g, ep, parts)
    assert m["RF"] >= 1.0 or g.num_vertices > sum(m["vertices"])
    for p in built:
        # CSR consistent
        assert p.out_indptr[-1] == p.num_edges
        assert p.in_indptr[-1] == p.num_edges
        assert (np.sort(p.in_edge_id) == np.arange(p.num_edges)).all()
        # global degrees >= local degrees
        assert (p.local_out_degree(np.arange(p.num_vertices)) <= p.out_degrees).all()


def test_reorder_permutations(small_graph):
    deg = small_graph.out_degrees() + small_graph.in_degrees()
    gids = np.arange(small_graph.num_vertices)
    pid = np.random.default_rng(0).integers(0, 4, small_graph.num_vertices)
    for alg in ("NS", "DS", "PS", "PDS"):
        perm = reorder_permutation(alg, global_ids=gids, degrees=deg, partition_ids=pid)
        assert sorted(perm.tolist()) == list(range(small_graph.num_vertices))
    pds = reorder_permutation("PDS", global_ids=gids, degrees=deg, partition_ids=pid)
    # PDS: partition ids non-decreasing; degree non-increasing within groups
    assert (np.diff(pid[pds]) >= 0).all()


def _assert_bfs_visit_order(indptr, indices, members, order):
    """``order`` must be a real BFS of the induced (symmetrized) subgraph:
    components contiguous, and within a component the visit order follows
    non-decreasing BFS layers from that component's first-visited vertex."""
    assert sorted(order.tolist()) == sorted(members.tolist())
    mset = set(int(v) for v in members)
    adj = {v: set() for v in mset}
    for v in mset:
        for u in indices[indptr[v] : indptr[v + 1]]:
            u = int(u)
            if u in mset:
                adj[v].add(u)
                adj[u].add(v)
    i, n = 0, len(order)
    while i < n:
        start = int(order[i])
        level = {start: 0}
        frontier = [start]
        while frontier:
            nxt = []
            for v in frontier:
                for u in adj[v]:
                    if u not in level:
                        level[u] = level[v] + 1
                        nxt.append(u)
            frontier = nxt
        comp = set(level)
        chunk = [int(v) for v in order[i : i + len(comp)]]
        assert set(chunk) == comp, "BFS component not contiguous in order"
        layers = [level[v] for v in chunk]
        assert layers == sorted(layers), "visit order violates BFS layers"
        i += len(comp)


def test_bfs_reorder_within_partitions(small_graph):
    """The within-partition reorder is a REAL induced-subgraph BFS (the old
    code hub-first degree-sorted each group)."""
    g = small_graph
    indptr, order = g.out_csr()
    indices = g.dst[order]
    deg = g.out_degrees() + g.in_degrees()
    pid = np.random.default_rng(3).integers(0, 4, g.num_vertices)
    perm = reorder_permutation(
        "BFS",
        global_ids=np.arange(g.num_vertices),
        degrees=deg,
        partition_ids=pid,
        indptr=indptr,
        indices=indices,
        seed=0,
    )
    assert sorted(perm.tolist()) == list(range(g.num_vertices))
    # groups appear in ascending partition order
    assert (np.diff(pid[perm]) >= 0).all()
    for p in np.unique(pid):
        members = np.flatnonzero(pid == p)
        group = perm[pid[perm] == p]
        _assert_bfs_visit_order(indptr, indices, members, group)
