"""The distributed sampling tier: wire-protocol roundtrips (property
tests), malformed-frame rejection, cross-mode bit-identity (inproc vs
forked workers over pipes/sockets), fault parity, crash->respawn
determinism, and the data-parallel trainer's equivalence to its
single-device reference step."""
import os
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.api import GLISPConfig, GLISPSystem
from repro.core.faults import FaultPlan, FaultSpec, RetryPolicy
from repro.core.sampling.service import (
    SampleRequest,
    SamplingSpec,
    ServiceStats,
)
from repro.dist.transport import (
    MESSAGE_TYPES,
    PROTOCOL_VERSION,
    ChannelClosed,
    DispatchResult,
    HealthRequest,
    HealthResponse,
    ProtocolError,
    ResetStatsAck,
    ResetStatsRequest,
    SampleDispatch,
    ShutdownAck,
    ShutdownRequest,
    StatsRequest,
    StatsResponse,
    TruncatedFrame,
    VersionMismatch,
    channel_pair,
    decode_frame,
    encode_frame,
    messages_equal,
)

pytestmark = pytest.mark.skipif(
    os.name != "posix", reason="dist workers fork (POSIX only)"
)


def _system(graph, **over):
    base = dict(num_parts=2, fanouts=(4, 3), batch_size=32, seed=5)
    base.update(over)
    return GLISPSystem.build(graph, GLISPConfig(**base))


def _sample(system, seeds, key, **spec_over):
    cfg = dict(fanouts=(4, 3))
    cfg.update(spec_over)
    spec = SamplingSpec(**cfg)
    ticket = system.backend.submit(
        SampleRequest(seeds=seeds, spec=spec, key=key)
    )
    return ticket.result(timeout=30.0)


def _assert_same_sub(a, b):
    np.testing.assert_array_equal(a.seeds, b.seeds)
    assert a.degraded == b.degraded
    assert a.lost_dispatches == b.lost_dispatches
    assert len(a.hops) == len(b.hops)
    for ha, hb in zip(a.hops, b.hops):
        np.testing.assert_array_equal(ha.src, hb.src)
        np.testing.assert_array_equal(ha.dst, hb.dst)
        np.testing.assert_array_equal(ha.eid, hb.eid)


# ---------------------------------------------------------------------------
# wire protocol: property roundtrips over every message type
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=0, max_value=40),
    hop=st.integers(min_value=0, max_value=5),
    part=st.integers(min_value=0, max_value=63),
    chunk=st.integers(min_value=0, max_value=7),
    fanout=st.integers(min_value=1, max_value=20),
    key_hi=st.integers(min_value=0, max_value=2**63 - 1),
    weighted=st.booleans(),
    replace=st.booleans(),
    direction=st.sampled_from(["out", "in"]),
)
def test_sample_dispatch_roundtrip(
    n, hop, part, chunk, fanout, key_hi, weighted, replace, direction
):
    msg = SampleDispatch(
        key=(key_hi, 3),
        hop=hop,
        part=part,
        chunk=chunk,
        seeds=np.arange(n, dtype=np.int64) * 7,
        fanout=fanout,
        direction=direction,
        weighted=weighted,
        replace=replace,
    )
    back = decode_frame(encode_frame(msg))
    assert type(back) is SampleDispatch
    assert messages_equal(msg, back)
    assert back.seeds.dtype == np.int64
    assert back.key == (key_hi, 3)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=0, max_value=50),
    lost=st.booleans(),
    retries=st.integers(min_value=0, max_value=9),
    failovers=st.integers(min_value=0, max_value=3),
    weighted=st.booleans(),
    wall=st.floats(min_value=0.0, max_value=50.0),
)
def test_dispatch_result_roundtrip(n, lost, retries, failovers, weighted, wall):
    if lost:
        n = 0  # degraded results carry empty arrays, like the real worker
    msg = DispatchResult(
        part=1,
        chunk=0,
        lost=lost,
        src=np.arange(n, dtype=np.int64),
        dst=np.arange(n, dtype=np.int64)[::-1].copy(),
        eid=np.arange(n, dtype=np.int64) + 1000,
        scores=np.linspace(0.0, 1.0, n) if weighted else None,
        retries=retries,
        failovers=failovers,
        wall_ms=wall,
        state={
            "replicas": {"server.1.0": {"requests": retries, "work_units": 1.5}},
            "breakers": [
                {"consecutive_failures": failovers, "opens": 0,
                 "cooldown_left": 0, "half_open": False}
            ],
            "injector": {"invocations": {"server.1.0": n}, "failures": {}},
        },
    )
    back = decode_frame(encode_frame(msg))
    assert type(back) is DispatchResult
    assert messages_equal(msg, back)
    assert back.state["replicas"]["server.1.0"]["work_units"] == 1.5


def test_control_frames_roundtrip():
    msgs = [
        StatsRequest(),
        StatsResponse(part=3, replicas={"server.3.0": {"requests": 7}}),
        HealthRequest(),
        HealthResponse(part=0, health={"server.0.0": "up"}),
        ResetStatsRequest(),
        ResetStatsAck(part=2),
        ShutdownRequest(),
        ShutdownAck(part=1),
    ]
    seen_types = {type(m) for m in msgs} | {SampleDispatch, DispatchResult}
    assert seen_types == set(MESSAGE_TYPES.values()), (
        "roundtrip tests must cover every registered message type"
    )
    for msg in msgs:
        back = decode_frame(encode_frame(msg))
        assert type(back) is type(msg)
        assert messages_equal(msg, back)


def test_version_mismatch_rejected():
    frame = bytearray(encode_frame(StatsRequest()))
    frame[4:6] = (PROTOCOL_VERSION + 1).to_bytes(2, "little")
    with pytest.raises(VersionMismatch):
        decode_frame(bytes(frame))


def test_malformed_frames_rejected():
    frame = encode_frame(
        DispatchResult(part=0, chunk=0, src=np.arange(5, dtype=np.int64))
    )
    with pytest.raises(TruncatedFrame):
        decode_frame(frame[:8])  # inside the header
    with pytest.raises(TruncatedFrame):
        decode_frame(frame[:-3])  # payload shorter than the header claims
    with pytest.raises(ProtocolError):
        decode_frame(b"NOPE" + frame[4:])  # bad magic
    bad_type = bytearray(frame)
    bad_type[6:8] = (999).to_bytes(2, "little")
    with pytest.raises(ProtocolError):
        decode_frame(bytes(bad_type))


@pytest.mark.parametrize("kind", ["mp", "socket"])
def test_channel_roundtrip_and_close(kind):
    a, b = channel_pair(kind)
    msg = SampleDispatch(
        key=(1, 2), hop=0, part=0, chunk=0,
        seeds=np.array([5, 9], dtype=np.int64),
        fanout=4, direction="out", weighted=False, replace=False,
    )
    a.send(msg)
    assert messages_equal(b.recv(), msg)
    b.send(ShutdownAck(part=0))
    assert a.poll(1.0)
    assert type(a.recv()) is ShutdownAck
    a.close()
    with pytest.raises(ChannelClosed):
        b.recv()
    b.close()


# ---------------------------------------------------------------------------
# cross-mode determinism: forked workers answer bit-identically
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("transport", ["mp", "socket"])
def test_remote_bit_identical_to_inproc(small_graph, transport):
    local = _system(small_graph)
    remote = _system(small_graph, dist_transport=transport)
    try:
        for i in range(4):
            seeds = np.arange(10 + 5 * i, dtype=np.int64) * 13 % 2000
            a = _sample(local, seeds, key=(77, i))
            b = _sample(remote, seeds, key=(77, i))
            _assert_same_sub(a, b)
        # weighted sampling threads scores through the wire too
        wa = _sample(local, np.arange(20, dtype=np.int64), key=(78, 0),
                     weighted=True)
        wb = _sample(remote, np.arange(20, dtype=np.int64), key=(78, 0),
                     weighted=True)
        _assert_same_sub(wa, wb)
    finally:
        remote.close()


def test_remote_stats_health_workloads(small_graph):
    local = _system(small_graph)
    remote = _system(small_graph, dist_transport="mp")
    try:
        seeds = np.arange(30, dtype=np.int64)
        _sample(local, seeds, key=(1, 0))
        _sample(remote, seeds, key=(1, 0))
        sl, sr = local.backend.stats(), remote.backend.stats()
        assert isinstance(sr, ServiceStats)
        assert sr.requests == sl.requests
        assert sr.work_units == pytest.approx(sl.work_units)
        # round work accounting must survive the move out of process
        assert sr.modeled_total_work == pytest.approx(sl.modeled_total_work)
        assert sr.modeled_parallel_work > 0
        np.testing.assert_allclose(
            remote.server_workloads(), local.server_workloads()
        )
        health = remote.server_health()
        assert health["worker.0"] == "up"
        assert health["worker.1"] == "up"
        assert all(v == "up" for k, v in health.items())
        remote.reset_stats()
        assert remote.backend.stats().requests == 0
    finally:
        remote.close()


def test_remote_fault_parity(small_graph):
    plan = FaultPlan(
        seed=13,
        sites=(("server.0.0", FaultSpec(p=0.4)),),
    )
    kw = dict(
        server_replicas=2,
        fault_plan=plan,
        retry_policy=RetryPolicy(max_attempts=2, base_delay_s=0.0),
    )
    local = _system(small_graph, **kw)
    remote = _system(small_graph, dist_transport="mp", **kw)
    try:
        for i in range(4):
            seeds = np.arange(25, dtype=np.int64) + 11 * i
            a = _sample(local, seeds, key=(9, i))
            b = _sample(remote, seeds, key=(9, i))
            _assert_same_sub(a, b)
        sl, sr = local.backend.stats(), remote.backend.stats()
        assert (sr.retries, sr.failovers, sr.degraded) == (
            sl.retries, sl.failovers, sl.degraded
        )
        assert sr.retries > 0  # the plan actually injected faults
    finally:
        remote.close()


def test_killed_worker_respawns_deterministically(small_graph):
    local = _system(small_graph)
    remote = _system(small_graph, dist_transport="mp")
    pool = remote.backend.service.dispatcher
    try:
        for i in range(3):
            seeds = np.arange(20, dtype=np.int64) + i
            _assert_same_sub(
                _sample(local, seeds, key=(4, i)),
                _sample(remote, seeds, key=(4, i)),
            )
        victim = pool._workers[1].proc
        victim.kill()
        victim.join(timeout=5.0)
        # post-kill requests respawn the worker from its last snapshot and
        # keep answering bit-identically
        for i in range(3, 6):
            seeds = np.arange(20, dtype=np.int64) + i
            _assert_same_sub(
                _sample(local, seeds, key=(4, i)),
                _sample(remote, seeds, key=(4, i)),
            )
        assert pool.respawn_count == 1
        sl, sr = local.backend.stats(), remote.backend.stats()
        assert sr.requests == sl.requests
    finally:
        remote.close()


def test_exhausted_respawn_budget_degrades(small_graph):
    remote = _system(small_graph, dist_transport="mp", worker_respawns=0)
    try:
        pool = remote.backend.service.dispatcher
        victim = pool._workers[0].proc
        victim.kill()
        victim.join(timeout=5.0)
        sub = _sample(remote, np.arange(12, dtype=np.int64), key=(2, 0))
        assert sub.degraded
        assert sub.lost_dispatches > 0
    finally:
        remote.close()


def test_pipeline_rejects_process_workers_with_remote_backend(small_graph):
    from repro.api import BatchPipeline

    remote = _system(small_graph, dist_transport="mp")
    try:
        with pytest.raises(ValueError, match="process"):
            BatchPipeline(
                remote.backend,
                remote.graph,
                np.arange(64, dtype=np.int64),
                [4, 3],
                2,
                workers="process",
            )
        # auto silently falls back to a thread producer
        pipe = BatchPipeline(
            remote.backend,
            remote.graph,
            np.arange(64, dtype=np.int64),
            [4, 3],
            2,
            batch_size=32,
            workers="auto",
            prefetch=1,
        )
        assert sum(1 for _ in pipe.batches(1)) == 2
    finally:
        remote.close()


# ---------------------------------------------------------------------------
# stats surface: modeled-vs-measured split, deprecated aliases
# ---------------------------------------------------------------------------


def test_service_stats_modeled_and_measured(small_graph):
    system = _system(small_graph)
    _sample(system, np.arange(40, dtype=np.int64), key=(3, 0))
    s = system.backend.stats()
    assert isinstance(s, ServiceStats)
    assert s.modeled_parallel_work > 0
    assert s.modeled_total_work >= s.modeled_parallel_work
    assert s.rounds > 0
    assert s.measured_round_seconds > 0
    # deprecated read aliases stay observable for one release
    assert s.parallel_work == s.modeled_parallel_work
    assert s.total_work == s.modeled_total_work
    svc = system.backend.service
    assert svc.parallel_work == s.modeled_parallel_work
    svc.parallel_work = 0.0  # legacy writers (benchmarks) still work
    assert svc.modeled_parallel_work == 0.0
    system.reset_stats()
    s2 = system.backend.stats()
    assert (s2.rounds, s2.measured_round_seconds) == (0, 0.0)


# ---------------------------------------------------------------------------
# data-parallel trainer: sharded step == single-device reference
# ---------------------------------------------------------------------------


def test_dp_trainer_matches_reference(small_graph):
    from repro.launch.mesh import make_local_mesh
    from repro.models.gnn.models import GNNModel

    system = _system(small_graph, fanouts=(4, 4))
    model = GNNModel("sage", 16, hidden=16, num_layers=2, num_classes=4)
    tr = system.dp_trainer(
        model,
        np.arange(96, dtype=np.int64),
        mesh=make_local_mesh(1),
        batch_size=32,
        reference=True,
    )
    log = tr.train(epochs=1, log_every=1, max_steps=3)
    assert len(log.losses) == 3
    np.testing.assert_allclose(log.losses, log.ref_losses, rtol=1e-5)
    assert log.sample_time > 0 and log.compute_time > 0


def test_stack_batches_pads_and_rejects_ragged():
    from repro.models.gnn.batching import GNNBatch
    from repro.train.data_parallel import stack_batches

    def mk(v, e, b):
        return GNNBatch(
            feats=np.ones((v, 4), dtype=np.float32),
            valid=np.ones(v, dtype=bool),
            seed_pos=np.zeros(b, dtype=np.int32),
            labels=np.zeros(b, dtype=np.int32),
            layer_dst=[np.zeros(e, dtype=np.int32)],
            layer_src=[np.zeros(e, dtype=np.int32)],
            layer_etype=[np.zeros(e, dtype=np.int32)],
        )

    stacked = stack_batches([mk(8, 6, 4), mk(5, 9, 4)])
    assert stacked.feats.shape == (2, 8, 4)
    assert stacked.layer_dst[0].shape == (2, 9)
    # padding rows are inert: invalid vertices, -1 edge endpoints
    assert not stacked.valid[1, 5:].any()
    assert (stacked.layer_dst[0][0, 6:] == -1).all()
    with pytest.raises(ValueError, match="seeds per batch"):
        stack_batches([mk(8, 6, 4), mk(8, 6, 3)])
