"""Launch layer: sharding rules, input specs, roofline parsing.  These run on
1 CPU device — the full 512-device lowering is exercised by dryrun.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.roofline import analytic_flops, parse_collectives
from repro.launch.specs import SHAPES, input_specs, params_shapes, resolve_config


class FakeMesh:
    """Just enough of a Mesh for the sharding rule functions."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_cover_all_leaves(arch):
    from repro.launch.shardings import param_specs

    cfg = get_config(arch)
    shapes = params_shapes(cfg)
    mesh = FakeMesh({"data": 16, "model": 16})
    specs = param_specs(cfg, shapes, mesh)
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_shapes) == len(flat_specs)
    # every sharded dim must divide
    for sh, sp in zip(flat_shapes, flat_specs):
        for dim, axis in zip(sh.shape, tuple(sp) + (None,) * 8):
            if axis == "model":
                assert dim % 16 == 0, (arch, sh.shape, sp)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_and_flops(arch, shape):
    cfg0 = get_config(arch)
    cfg = resolve_config(cfg0, shape)
    assert cfg is not None  # no skipped combinations in this pool
    ins = input_specs(cfg, shape)
    sh = SHAPES[shape]
    b = sh["batch"]
    s = sh["seq"] if sh["kind"] != "decode" else 1
    assert ins["inputs"].shape[0] == b
    assert ins["inputs"].shape[1] == s
    fl = analytic_flops(cfg, shape)
    assert fl["total"] > 0
    # 6ND cross-check within a loose band for token-input training shapes
    if sh["kind"] == "train" and cfg.input_mode == "tokens":
        ratio = fl["total"] / fl["6nd"]
        assert 0.5 < ratio < 6.0, (arch, shape, ratio)


def test_parse_collectives_with_while_loop():
    hlo = """
HloModule test

%body (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p = (s32[], f32[128]) parameter(0)
  %ar = f32[128]{0} all-reduce(%x), replica_groups={}
  ROOT %t = (s32[], f32[128]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[128])) -> pred[] {
  %p = (s32[], f32[128]) parameter(0)
  %c = s32[] constant(24)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[128]) -> f32[128] {
  %ag = f32[256]{0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[128]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[128] get-tuple-element(%w), index=1
}
"""
    out = parse_collectives(hlo)
    assert out["counts"]["all-gather"] == 1
    assert out["counts"]["all-reduce"] == 24  # multiplied by trip count
    assert out["bytes"]["all-reduce"] == 24 * 128 * 4
    assert out["bytes"]["all-gather"] == 256 * 4


def test_mesh_constants():
    from repro.launch.mesh import HW

    assert HW["peak_flops_bf16"] == 197e12
    assert HW["hbm_bw"] == 819e9


def test_long_context_resolution():
    cfg = get_config("gemma-2b")
    lc = resolve_config(cfg, "long_500k")
    assert lc.window == cfg.long_context_window  # windowed variant
    native = resolve_config(get_config("mamba2-130m"), "long_500k")
    assert native.window == 0  # unchanged: natively sub-quadratic


def test_pad_heads_for_mesh():
    from repro.launch.specs import pad_heads_for_mesh

    cfg = get_config("llava-next-34b")  # 56H, kv=8
    padded = pad_heads_for_mesh(cfg, 16)
    assert (padded.padded_q_heads, padded.padded_kv_heads) == (64, 8)
    cfg = get_config("internlm2-1.8b")  # 16H kv=8: already tiles (no pad)
    padded = pad_heads_for_mesh(cfg, 16)
    assert padded.q_head_pad == 0 and padded.tp_size == 16
    cfg = get_config("gemma-2b")  # 8H kv=1: pad ratio 2.0 > 1.5 -> skipped
    padded = pad_heads_for_mesh(cfg, 16)
    assert padded.q_head_pad == 0
    cfg = get_config("musicgen-medium")  # 24H MHA -> pad to 32/32
    padded = pad_heads_for_mesh(cfg, 16)
    assert (padded.padded_q_heads, padded.padded_kv_heads) == (32, 32)


def test_moe_grouped_dispatch_matches_global_routing_shape():
    """Grouped dispatch preserves shapes/finite outputs (semantics differ by
    per-group capacity, which is the point)."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.models.transformer.config import ArchConfig, MoEConfig
    from repro.models.transformer.model import forward, init_params

    cfg = ArchConfig(name="gm", family="moe", num_layers=2, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=0, vocab_size=256,
                     moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=64,
                                   capacity_factor=2.0),
                     dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    inp = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 256)
    lg1, aux1, _ = forward(params, cfg, inp)
    cfg2 = dataclasses.replace(cfg, moe_dispatch_groups=4)
    lg2, aux2, _ = forward(params, cfg2, inp)
    assert lg1.shape == lg2.shape
    assert bool(jnp.isfinite(lg2).all()) and float(aux2) > 0
